//! Experiment configuration: JSON specs mirroring the paper's §4.2
//! setting, so every run is reproducible from a file under configs/.

use std::path::Path;

use crate::bandwidth::TraceSpec;
use crate::coordinator::{ComputeModel, ExecMode};
use crate::kimad::{BudgetParams, CompressPolicy};
use crate::util::json::Value;

/// Where the round engine's messages travel: the single-process
/// virtual-time engine, or real frames over localhost sockets between
/// a coordinator and M worker peers (`transport::run_wired`). The wire
/// transports carry byte-identical per-round payloads to `Inproc` —
/// only arrival timestamps differ — which the transport layer verifies
/// frame by frame at runtime.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum TransportSpec {
    /// Virtual-time, in-process rounds (the default).
    #[default]
    Inproc,
    /// Length-prefixed frames over localhost TCP.
    Tcp,
    /// Length-prefixed frames over a Unix-domain socket.
    Uds,
}

impl TransportSpec {
    /// Parse a CLI/JSON token: `inproc`, `tcp`, or `uds`.
    pub fn parse(token: &str) -> anyhow::Result<Self> {
        Ok(match token {
            "inproc" => TransportSpec::Inproc,
            "tcp" => TransportSpec::Tcp,
            "uds" => TransportSpec::Uds,
            other => anyhow::bail!("unknown transport '{other}' (want inproc, tcp or uds)"),
        })
    }

    /// The token [`parse`](Self::parse) accepts.
    pub fn as_str(self) -> &'static str {
        match self {
            TransportSpec::Inproc => "inproc",
            TransportSpec::Tcp => "tcp",
            TransportSpec::Uds => "uds",
        }
    }

    /// Does this config cross a real socket (and hence spawn worker
    /// peers) instead of running the in-process engine?
    pub fn is_wire(self) -> bool {
        !matches!(self, TransportSpec::Inproc)
    }
}

/// Declarative execution mode, resolved against the worker count M at
/// simulation build time (so one spec can drive cells with different
/// M in a scenario grid).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ExecModeSpec {
    /// Lockstep rounds (the paper's loop).
    Sync,
    /// First-K quorum rounds: the server aggregates after
    /// `ceil(participation · M)` arrivals (`participation` in (0, 1]).
    SemiSync { participation: f64 },
    /// One server step per arrival, γ damped by `damping^staleness`.
    Async { damping: f64 },
}

impl ExecModeSpec {
    /// Resolve the spec for a concrete worker count.
    pub fn resolve(&self, m: usize) -> ExecMode {
        match *self {
            ExecModeSpec::Sync => ExecMode::Sync,
            ExecModeSpec::SemiSync { participation } => ExecMode::SemiSync {
                quorum: ((participation * m as f64).ceil() as usize).clamp(1, m.max(1)),
            },
            ExecModeSpec::Async { damping } => ExecMode::Async { damping },
        }
    }

    /// Short CLI/cell-id name.
    pub fn name(&self) -> &'static str {
        match self {
            ExecModeSpec::Sync => "sync",
            ExecModeSpec::SemiSync { .. } => "semisync",
            ExecModeSpec::Async { .. } => "async",
        }
    }

    /// Parse a CLI token: `sync`, `semisync`, `async`, optionally with
    /// a parameter suffix — `semisync:0.75` (participation),
    /// `async:0.9` (damping). Parameters are range-checked here so a
    /// bad sweep fails at the CLI instead of panicking mid-grid.
    pub fn parse(token: &str) -> anyhow::Result<Self> {
        let (name, param) = match token.split_once(':') {
            Some((n, p)) => (n, Some(p)),
            None => (token, None),
        };
        let num = |p: Option<&str>, default: f64| -> anyhow::Result<f64> {
            match p {
                None => Ok(default),
                Some(p) => p
                    .parse()
                    .map_err(|e| anyhow::anyhow!("mode parameter '{p}': {e}")),
            }
        };
        Ok(match name {
            "sync" => {
                anyhow::ensure!(param.is_none(), "sync takes no parameter");
                ExecModeSpec::Sync
            }
            "semisync" => {
                ExecModeSpec::SemiSync { participation: check_participation(num(param, 0.5)?)? }
            }
            "async" => ExecModeSpec::Async { damping: check_damping(num(param, 0.5)?)? },
            other => anyhow::bail!("unknown execution mode '{other}' (sync|semisync|async)"),
        })
    }

    pub fn to_json(&self) -> Value {
        match self {
            ExecModeSpec::Sync => Value::obj(vec![("kind", Value::str("sync"))]),
            ExecModeSpec::SemiSync { participation } => Value::obj(vec![
                ("kind", Value::str("semi_sync")),
                ("participation", Value::num(*participation)),
            ]),
            ExecModeSpec::Async { damping } => Value::obj(vec![
                ("kind", Value::str("async")),
                ("damping", Value::num(*damping)),
            ]),
        }
    }

    pub fn from_json(v: &Value) -> anyhow::Result<Self> {
        Ok(match v.get("kind")?.as_str()? {
            "sync" => ExecModeSpec::Sync,
            "semi_sync" => ExecModeSpec::SemiSync {
                participation: check_participation(
                    v.opt("participation")
                        .and_then(|x| x.as_f64().ok())
                        .unwrap_or(0.5),
                )?,
            },
            "async" => ExecModeSpec::Async {
                damping: check_damping(
                    v.opt("damping").and_then(|x| x.as_f64().ok()).unwrap_or(0.5),
                )?,
            },
            other => anyhow::bail!("unknown execution mode kind '{other}'"),
        })
    }
}

fn check_participation(p: f64) -> anyhow::Result<f64> {
    anyhow::ensure!(
        p > 0.0 && p <= 1.0,
        "semisync participation must be in (0, 1], got {p}"
    );
    Ok(p)
}

/// Range check for the *population* participation fraction (per-round
/// client sampling — distinct from semisync's race-based first-K
/// quorum, which is `ExecModeSpec::SemiSync`).
pub fn check_pop_participation(p: f64) -> anyhow::Result<f64> {
    anyhow::ensure!(
        p.is_finite() && p > 0.0 && p <= 1.0,
        "population participation must be in (0, 1], got {p}"
    );
    Ok(p)
}

/// Default cohort count for population runs that leave `cohorts` at
/// auto (0): enough link/compute diversity to be interesting, small
/// enough that per-round probing stays O(1)-ish at any M.
pub const DEFAULT_COHORTS: usize = 64;

fn check_damping(d: f64) -> anyhow::Result<f64> {
    anyhow::ensure!(d > 0.0 && d <= 1.0, "async damping must be in (0, 1], got {d}");
    Ok(d)
}

/// JSON codec for a [`ComputeModel`] (shared with `scenarios`).
pub fn compute_to_json(c: &ComputeModel) -> Value {
    match c {
        ComputeModel::Constant => Value::obj(vec![("kind", Value::str("constant"))]),
        ComputeModel::Lognormal { sigma, seed } => Value::obj(vec![
            ("kind", Value::str("lognormal")),
            ("sigma", Value::num(*sigma)),
            ("seed", Value::num(*seed as f64)),
        ]),
        ComputeModel::Profile { factors } => Value::obj(vec![
            ("kind", Value::str("profile")),
            (
                "factors",
                Value::Arr(factors.iter().map(|&f| Value::num(f)).collect()),
            ),
        ]),
    }
}

/// Inverse of [`compute_to_json`].
pub fn compute_from_json(v: &Value) -> anyhow::Result<ComputeModel> {
    Ok(match v.get("kind")?.as_str()? {
        "constant" => ComputeModel::Constant,
        "lognormal" => ComputeModel::Lognormal {
            sigma: v.get("sigma")?.as_f64()?,
            seed: v.opt("seed").and_then(|x| x.as_u64().ok()).unwrap_or(21),
        },
        "profile" => ComputeModel::Profile {
            factors: v
                .get("factors")?
                .as_arr()?
                .iter()
                .map(|f| f.as_f64())
                .collect::<anyhow::Result<Vec<_>>>()?,
        },
        other => anyhow::bail!("unknown compute model kind '{other}'"),
    })
}

/// Which workload drives gradients.
#[derive(Debug, Clone, PartialEq)]
pub enum WorkloadSpec {
    /// §4.1 quadratic: f(x) = ½ Σ a_i x_i², log-spaced a over [1,10].
    Quadratic { d: usize, n_layers: usize, t_comp: f64 },
    /// Deep model from artifacts/ (preset = tiny|small|e2e|big).
    DeepModel {
        preset: String,
        /// Dataset noise σ.
        sigma: f32,
        /// T_comp override; <= 0 means the §4.2 convention
        /// ModelSize / AverageBandwidth.
        t_comp: f64,
    },
}

impl WorkloadSpec {
    /// Parse a CLI token:
    ///
    /// * `quad` or `quad:d=30,layers=3,tcomp=0.1` — the §4.1 quadratic
    ///   (missing keys take the defaults shown);
    /// * `deep:<preset>` or `deep:tiny,sigma=0.3,tcomp=0` — a deep
    ///   model from artifacts/ (`tcomp<=0` = the §4.2 convention
    ///   ModelSize / AverageBandwidth).
    ///
    /// Like `ExecModeSpec::parse`, bad tokens fail at the CLI instead
    /// of mid-grid.
    pub fn parse(token: &str) -> anyhow::Result<Self> {
        let (name, rest) = match token.split_once(':') {
            Some((n, r)) => (n, r),
            None => (token, ""),
        };
        let mut pairs: Vec<(&str, &str)> = Vec::new();
        let mut head = "";
        for (i, part) in rest.split(',').enumerate() {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            match part.split_once('=') {
                Some((k, v)) => pairs.push((k.trim(), v.trim())),
                None if i == 0 => head = part,
                None => anyhow::bail!("workload parameter '{part}' is not key=value"),
            }
        }
        let lookup = |key: &str| pairs.iter().find(|(k, _)| *k == key).map(|(_, v)| *v);
        let num = |key: &str, default: f64| -> anyhow::Result<f64> {
            match lookup(key) {
                None => Ok(default),
                Some(v) => {
                    let n: f64 =
                        v.parse().map_err(|e| anyhow::anyhow!("workload {key}='{v}': {e}"))?;
                    anyhow::ensure!(
                        n.is_finite() && n >= 0.0,
                        "workload {key} must be finite and >= 0, got {v}"
                    );
                    Ok(n)
                }
            }
        };
        for (k, _) in &pairs {
            anyhow::ensure!(
                ["d", "layers", "tcomp", "sigma"].contains(k),
                "unknown workload parameter '{k}' (d|layers|tcomp|sigma)"
            );
        }
        let int = |key: &str, default: usize| -> anyhow::Result<usize> {
            match lookup(key) {
                None => Ok(default),
                Some(v) => {
                    let n: usize = v
                        .parse()
                        .map_err(|e| anyhow::anyhow!("workload {key}='{v}': {e}"))?;
                    anyhow::ensure!(n >= 1, "workload {key} must be >= 1, got {v}");
                    Ok(n)
                }
            }
        };
        Ok(match name {
            "quad" => {
                anyhow::ensure!(head.is_empty(), "quad takes key=value parameters, not '{head}'");
                anyhow::ensure!(lookup("sigma").is_none(), "sigma is a deep-model parameter");
                WorkloadSpec::Quadratic {
                    d: int("d", 30)?,
                    n_layers: int("layers", 3)?,
                    t_comp: num("tcomp", 0.1)?,
                }
            }
            "deep" => {
                anyhow::ensure!(
                    !head.is_empty(),
                    "deep needs a preset: deep:<tiny|small|e2e|big>"
                );
                anyhow::ensure!(lookup("d").is_none(), "d is a quadratic parameter");
                anyhow::ensure!(lookup("layers").is_none(), "layers is a quadratic parameter");
                WorkloadSpec::DeepModel {
                    preset: head.to_string(),
                    sigma: num("sigma", 0.3)? as f32,
                    t_comp: num("tcomp", 0.0)?,
                }
            }
            other => anyhow::bail!("unknown workload '{other}' (quad|deep)"),
        })
    }

    /// Short cell-id/table token: `quad30l3`, `deep-tiny`. Non-default
    /// `tcomp`/`sigma` values are embedded (`quad30l3-tc0.5`,
    /// `deep-tiny-sg0.5`) so one grid can sweep them — mirroring how
    /// parameterized modes name themselves (`semisync0.75`).
    pub fn short_name(&self) -> String {
        match self {
            WorkloadSpec::Quadratic { d, n_layers, t_comp } => {
                let mut s = format!("quad{d}l{n_layers}");
                if *t_comp != 0.1 {
                    s.push_str(&format!("-tc{t_comp}"));
                }
                s
            }
            WorkloadSpec::DeepModel { preset, sigma, t_comp } => {
                let mut s = format!("deep-{preset}");
                if *sigma != 0.3 {
                    s.push_str(&format!("-sg{sigma}"));
                }
                if *t_comp > 0.0 {
                    s.push_str(&format!("-tc{t_comp}"));
                }
                s
            }
        }
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct OptimizerSpec {
    pub gamma: f64,
    /// Per-layer weights w_i (empty = 1.0 everywhere).
    pub layer_weights: Vec<f64>,
}

/// A full experiment: the unit both the CLI and the benches consume.
#[derive(Debug, Clone, PartialEq)]
pub struct ExperimentConfig {
    pub name: String,
    /// Number of workers M. With `participation < 1.0` (or an explicit
    /// `cohorts`), M is a *population* size: clients exist as weighted
    /// cohorts and only a sampled quorum materializes per round — see
    /// `coordinator::population`.
    pub m: usize,
    /// Per-round participation fraction p in (0, 1]: each round samples
    /// `ceil(p · M)` clients (deterministically from `seed`). 1.0 with
    /// `cohorts == 0` = the dense path (every client is a resident
    /// worker, exactly the pre-population engine).
    pub participation: f64,
    /// Cohort count C for population runs: clients share their cohort's
    /// bandwidth traces and link monitors (`client % C`). 0 = auto
    /// (`min(M, DEFAULT_COHORTS)` when sampling, dense otherwise);
    /// `cohorts == M` reproduces dense per-worker traces exactly.
    pub cohorts: usize,
    pub workload: WorkloadSpec,
    pub budget: BudgetParams,
    pub up_policy: CompressPolicy,
    pub down_policy: CompressPolicy,
    pub optimizer: OptimizerSpec,
    /// Uplink bandwidth pattern (per-worker variants derived).
    pub uplink: TraceSpec,
    /// Downlink pattern.
    pub downlink: TraceSpec,
    /// Broadcast congestion coefficient α (§3.1); 1.0 = none.
    pub alpha: f64,
    /// Rounds to simulate.
    pub rounds: u64,
    /// Cold-start bandwidth prior (bits/s); <= 0 = mean of the pattern.
    pub prior_bps: f64,
    pub warm_start: bool,
    /// Use the whole model as ONE compression layer (plain Kimad);
    /// false = per-layer (Kimad+ granularity).
    pub single_layer: bool,
    /// Safety factor on the Eq. (2) budget (see SimConfig).
    pub budget_safety: f64,
    /// Worker-phase thread count (see `SimConfig::threads`): 0 = auto,
    /// 1 = serial. Results are bit-identical for every setting.
    pub threads: usize,
    /// Server-shard count for the aggregation and broadcast paths (see
    /// `Simulation::shards`): 0 = auto, 1 = serialized, n = at most n
    /// layer shards. Results are bit-identical for every setting.
    pub shards: usize,
    /// Cooperative thread budget (see `Simulation::thread_cap`): an
    /// upper bound on what the auto knobs (`threads = 0`,
    /// `shards = 0`) may resolve to; 0 = the machine. The scenario
    /// matrix sets this per cell so matrix workers × per-cell threads
    /// never oversubscribes the box. Never changes results.
    pub thread_cap: usize,
    /// Round-engine execution mode (sync / semi-sync / async).
    pub mode: ExecModeSpec,
    /// Per-worker compute-time model (straggler profiles).
    pub compute: ComputeModel,
    /// Message transport: in-process virtual time (default) or real
    /// frames over TCP / Unix sockets (Sync dense runs only). Wire
    /// payloads are byte-identical to inproc per round; only arrival
    /// timestamps differ.
    pub transport: TransportSpec,
    pub seed: u64,
}

// ---------------------------------------------------------------------
// JSON codecs
// ---------------------------------------------------------------------

fn budget_to_json(b: &BudgetParams) -> Value {
    match b {
        BudgetParams::RoundBudget { t, t_comp } => Value::obj(vec![
            ("mode", Value::str("round_budget")),
            ("t", Value::num(*t)),
            ("t_comp", Value::num(*t_comp)),
        ]),
        BudgetParams::PerDirection { t_comm } => Value::obj(vec![
            ("mode", Value::str("per_direction")),
            ("t_comm", Value::num(*t_comm)),
        ]),
    }
}

fn budget_from_json(v: &Value) -> anyhow::Result<BudgetParams> {
    Ok(match v.get("mode")?.as_str()? {
        "round_budget" => BudgetParams::RoundBudget {
            t: v.get("t")?.as_f64()?,
            t_comp: v.get("t_comp")?.as_f64()?,
        },
        "per_direction" => BudgetParams::PerDirection { t_comm: v.get("t_comm")?.as_f64()? },
        other => anyhow::bail!("unknown budget mode '{other}'"),
    })
}

/// JSON codec for a [`CompressPolicy`] (shared with `scenarios`).
pub fn policy_to_json(p: &CompressPolicy) -> Value {
    match p {
        CompressPolicy::FixedRatio { ratio } => Value::obj(vec![
            ("kind", Value::str("fixed_ratio")),
            ("ratio", Value::num(*ratio)),
        ]),
        CompressPolicy::KimadUniform => {
            Value::obj(vec![("kind", Value::str("kimad_uniform"))])
        }
        CompressPolicy::KimadPlus { discretization, ratios } => Value::obj(vec![
            ("kind", Value::str("kimad_plus")),
            ("discretization", Value::num(*discretization as f64)),
            (
                "ratios",
                Value::Arr(ratios.iter().map(|&r| Value::num(r)).collect()),
            ),
        ]),
        CompressPolicy::WholeModelTopK => {
            Value::obj(vec![("kind", Value::str("whole_model_topk"))])
        }
    }
}

/// Inverse of [`policy_to_json`].
pub fn policy_from_json(v: &Value) -> anyhow::Result<CompressPolicy> {
    Ok(match v.get("kind")?.as_str()? {
        "fixed_ratio" => CompressPolicy::FixedRatio { ratio: v.get("ratio")?.as_f64()? },
        "kimad_uniform" => CompressPolicy::KimadUniform,
        "kimad_plus" => CompressPolicy::KimadPlus {
            discretization: v.get("discretization")?.as_usize()?,
            ratios: match v.opt("ratios") {
                None => vec![],
                Some(a) => a
                    .as_arr()?
                    .iter()
                    .map(|r| r.as_f64())
                    .collect::<anyhow::Result<Vec<_>>>()?,
            },
        },
        "whole_model_topk" => CompressPolicy::WholeModelTopK,
        other => anyhow::bail!("unknown policy kind '{other}'"),
    })
}

/// JSON codec for a [`WorkloadSpec`] (shared with `scenarios`).
pub fn workload_to_json(w: &WorkloadSpec) -> Value {
    match w {
        WorkloadSpec::Quadratic { d, n_layers, t_comp } => Value::obj(vec![
            ("kind", Value::str("quadratic")),
            ("d", Value::num(*d as f64)),
            ("n_layers", Value::num(*n_layers as f64)),
            ("t_comp", Value::num(*t_comp)),
        ]),
        WorkloadSpec::DeepModel { preset, sigma, t_comp } => Value::obj(vec![
            ("kind", Value::str("deep_model")),
            ("preset", Value::str(preset.clone())),
            ("sigma", Value::num(*sigma as f64)),
            ("t_comp", Value::num(*t_comp)),
        ]),
    }
}

/// Inverse of [`workload_to_json`].
pub fn workload_from_json(v: &Value) -> anyhow::Result<WorkloadSpec> {
    Ok(match v.get("kind")?.as_str()? {
        "quadratic" => WorkloadSpec::Quadratic {
            d: v.get("d")?.as_usize()?,
            n_layers: v.get("n_layers")?.as_usize()?,
            t_comp: v.get("t_comp")?.as_f64()?,
        },
        "deep_model" => WorkloadSpec::DeepModel {
            preset: v.get("preset")?.as_str()?.to_string(),
            sigma: v.opt("sigma").and_then(|s| s.as_f64().ok()).unwrap_or(0.3) as f32,
            t_comp: v.opt("t_comp").and_then(|s| s.as_f64().ok()).unwrap_or(0.0),
        },
        other => anyhow::bail!("unknown workload kind '{other}'"),
    })
}

impl ExperimentConfig {
    pub fn to_json(&self) -> Value {
        let mut fields = vec![
            ("name", Value::str(self.name.clone())),
            ("m", Value::num(self.m as f64)),
            ("participation", Value::num(self.participation)),
            ("cohorts", Value::num(self.cohorts as f64)),
            ("workload", workload_to_json(&self.workload)),
            ("budget", budget_to_json(&self.budget)),
            ("up_policy", policy_to_json(&self.up_policy)),
            ("down_policy", policy_to_json(&self.down_policy)),
            (
                "optimizer",
                Value::obj(vec![
                    ("gamma", Value::num(self.optimizer.gamma)),
                    (
                        "layer_weights",
                        Value::Arr(
                            self.optimizer
                                .layer_weights
                                .iter()
                                .map(|&w| Value::num(w))
                                .collect(),
                        ),
                    ),
                ]),
            ),
            ("uplink", self.uplink.to_json()),
            ("downlink", self.downlink.to_json()),
            ("alpha", Value::num(self.alpha)),
            ("rounds", Value::num(self.rounds as f64)),
            ("prior_bps", Value::num(self.prior_bps)),
            ("warm_start", Value::Bool(self.warm_start)),
            ("single_layer", Value::Bool(self.single_layer)),
            ("budget_safety", Value::num(self.budget_safety)),
            ("threads", Value::num(self.threads as f64)),
            ("shards", Value::num(self.shards as f64)),
            ("thread_cap", Value::num(self.thread_cap as f64)),
            ("mode", self.mode.to_json()),
            ("compute", compute_to_json(&self.compute)),
        ];
        // Emitted only off the default so pre-transport config JSON
        // stays byte-identical (the warm-reuse CI checks `cmp` it).
        if self.transport.is_wire() {
            fields.push(("transport", Value::str(self.transport.as_str())));
        }
        fields.push(("seed", Value::num(self.seed as f64)));
        Value::obj(fields)
    }

    pub fn from_json(v: &Value) -> anyhow::Result<Self> {
        Ok(Self {
            name: v.get("name")?.as_str()?.to_string(),
            m: v.get("m")?.as_usize()?,
            // Absent in pre-population configs: dense p = 1.
            participation: check_pop_participation(
                v.opt("participation")
                    .and_then(|a| a.as_f64().ok())
                    .unwrap_or(1.0),
            )?,
            cohorts: v.opt("cohorts").and_then(|a| a.as_usize().ok()).unwrap_or(0),
            workload: workload_from_json(v.get("workload")?)?,
            budget: budget_from_json(v.get("budget")?)?,
            up_policy: policy_from_json(v.get("up_policy")?)?,
            down_policy: policy_from_json(v.get("down_policy")?)?,
            optimizer: {
                let o = v.get("optimizer")?;
                OptimizerSpec {
                    gamma: o.get("gamma")?.as_f64()?,
                    layer_weights: match o.opt("layer_weights") {
                        None => vec![],
                        Some(a) => a
                            .as_arr()?
                            .iter()
                            .map(|w| w.as_f64())
                            .collect::<anyhow::Result<Vec<_>>>()?,
                    },
                }
            },
            uplink: TraceSpec::from_json(v.get("uplink")?)?,
            downlink: TraceSpec::from_json(v.get("downlink")?)?,
            alpha: v.opt("alpha").and_then(|a| a.as_f64().ok()).unwrap_or(1.0),
            rounds: v.get("rounds")?.as_u64()?,
            prior_bps: v.opt("prior_bps").and_then(|a| a.as_f64().ok()).unwrap_or(0.0),
            warm_start: v
                .opt("warm_start")
                .and_then(|a| a.as_bool().ok())
                .unwrap_or(true),
            single_layer: v
                .opt("single_layer")
                .and_then(|a| a.as_bool().ok())
                .unwrap_or(false),
            budget_safety: v
                .opt("budget_safety")
                .and_then(|a| a.as_f64().ok())
                .unwrap_or(1.0),
            threads: v
                .opt("threads")
                .and_then(|a| a.as_usize().ok())
                .unwrap_or(0),
            shards: v
                .opt("shards")
                .and_then(|a| a.as_usize().ok())
                .unwrap_or(0),
            thread_cap: v
                .opt("thread_cap")
                .and_then(|a| a.as_usize().ok())
                .unwrap_or(0),
            mode: match v.opt("mode") {
                None => ExecModeSpec::Sync,
                Some(m) => ExecModeSpec::from_json(m)?,
            },
            compute: match v.opt("compute") {
                None => ComputeModel::Constant,
                Some(c) => compute_from_json(c)?,
            },
            transport: match v.opt("transport") {
                None => TransportSpec::Inproc,
                Some(t) => TransportSpec::parse(t.as_str()?)?,
            },
            seed: v.opt("seed").and_then(|a| a.as_u64().ok()).unwrap_or(21),
        })
    }

    pub fn from_json_file(path: &Path) -> anyhow::Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        Self::from_json(&Value::parse(&text)?)
    }

    pub fn to_json_string(&self) -> String {
        self.to_json().to_string()
    }

    /// The canonical serialization content-addressed result caches
    /// hash (`scenarios::cache`, docs/ARCHITECTURE.md §11): the full
    /// config JSON minus the runtime-only `transport` field. Two
    /// configs are byte-equal here iff they describe the same
    /// experiment:
    ///
    /// * keys emit sorted (`util::json::Value` objects are `BTreeMap`s),
    ///   so the construction site — hand-built struct, grid expansion,
    ///   or a `from_json` round trip — never changes the bytes;
    /// * `transport` is stripped because results are
    ///   transport-invariant by the wire-bit-identity contract (a cell
    ///   run over TCP must hit the cache entry its in-process twin
    ///   wrote, and vice versa).
    ///
    /// Thread/shard knobs stay in: they are part of the config a cell
    /// declares (the matrix serializes cells *pre*-clamp, so the bytes
    /// are machine-independent), and distinct shard-axis cells are
    /// distinct experiments by id anyway.
    pub fn canonical_json(&self) -> String {
        let mut c = self.clone();
        c.transport = TransportSpec::Inproc;
        c.to_json().to_string()
    }

    /// Does this config use the population engine (sampled per-round
    /// participation and/or cohort-shared links) instead of the dense
    /// per-worker path? `participation = 1` with auto cohorts is dense
    /// by definition — the population engine at p = 1, C = M is
    /// bit-identical to it, so routing there would only cost clarity.
    pub fn is_population(&self) -> bool {
        self.participation < 1.0 || self.cohorts != 0
    }

    /// Per-round sampled quorum size: `ceil(p · M)`, never below one
    /// client, never above the population.
    pub fn quorum(&self) -> usize {
        ((self.participation * self.m as f64).ceil() as usize).clamp(1, self.m.max(1))
    }

    /// Resolved cohort count C for population runs: the explicit knob
    /// clamped to M, else `min(M, DEFAULT_COHORTS)`.
    pub fn resolved_cohorts(&self) -> usize {
        let m = self.m.max(1);
        if self.cohorts != 0 {
            self.cohorts.min(m)
        } else {
            m.min(DEFAULT_COHORTS)
        }
    }

    /// How many physical netsim links this config needs: one per worker
    /// on the dense path, one per cohort under the population model —
    /// the quantity trace building, family sharing and the netsim
    /// assembly all key on.
    pub fn n_links(&self) -> usize {
        if self.is_population() {
            self.resolved_cohorts()
        } else {
            self.m
        }
    }

    /// Cap this experiment's intra-simulation parallelism to `budget`
    /// concurrent threads — the cooperative thread-budget rule: a
    /// scenario matrix running W cell workers hands each cell at most
    /// `available_parallelism / W` threads, so W × budget never
    /// oversubscribes the box (the pre-PR-4 bug spawned up to N×N
    /// threads on an N-core machine). Auto knobs (0) keep their
    /// small-work serial floor via `thread_cap`; explicit knobs are
    /// clamped down, never up. Results are unaffected — thread and
    /// shard counts are bit-invariant by the engine contract.
    pub fn clamp_parallelism(&mut self, budget: usize) {
        let b = budget.max(1);
        self.thread_cap = if self.thread_cap == 0 { b } else { self.thread_cap.min(b) };
        if self.threads != 0 {
            self.threads = self.threads.min(b);
        }
        if self.shards != 0 {
            self.shards = self.shards.min(b);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> ExperimentConfig {
        ExperimentConfig {
            name: "fig8".into(),
            m: 4,
            participation: 1.0,
            cohorts: 0,
            workload: WorkloadSpec::DeepModel {
                preset: "e2e".into(),
                sigma: 0.3,
                t_comp: 0.0,
            },
            budget: BudgetParams::PerDirection { t_comm: 1.0 },
            up_policy: CompressPolicy::KimadPlus { discretization: 1000, ratios: vec![0.1, 0.5] },
            down_policy: CompressPolicy::KimadUniform,
            optimizer: OptimizerSpec { gamma: 0.01, layer_weights: vec![1.0, 0.5] },
            uplink: TraceSpec::SinSquared { eta: 300e6, theta: 0.7, delta: 30e6, phase: 0.0 },
            downlink: TraceSpec::Constant { bps: 1e9 },
            alpha: 1.0,
            rounds: 100,
            prior_bps: 0.0,
            warm_start: true,
            single_layer: false,
            budget_safety: 0.9,
            threads: 0,
            shards: 2,
            thread_cap: 0,
            mode: ExecModeSpec::SemiSync { participation: 0.75 },
            compute: ComputeModel::Lognormal { sigma: 0.3, seed: 7 },
            transport: TransportSpec::Inproc,
            seed: 21,
        }
    }

    #[test]
    fn json_roundtrip() {
        let cfg = sample();
        let text = cfg.to_json_string();
        let back = ExperimentConfig::from_json(&Value::parse(&text).unwrap()).unwrap();
        assert_eq!(back, cfg);
    }

    #[test]
    fn roundtrip_all_variants() {
        let mut cfg = sample();
        cfg.workload = WorkloadSpec::Quadratic { d: 30, n_layers: 3, t_comp: 0.1 };
        cfg.budget = BudgetParams::RoundBudget { t: 1.0, t_comp: 0.2 };
        cfg.up_policy = CompressPolicy::FixedRatio { ratio: 0.2 };
        cfg.down_policy = CompressPolicy::WholeModelTopK;
        cfg.mode = ExecModeSpec::Async { damping: 0.8 };
        cfg.compute = ComputeModel::Profile { factors: vec![1.0, 2.0, 4.0] };
        let back =
            ExperimentConfig::from_json(&Value::parse(&cfg.to_json_string()).unwrap()).unwrap();
        assert_eq!(back, cfg);
    }

    #[test]
    fn transport_roundtrip_and_backcompat() {
        // Default transport is invisible in JSON: pre-transport configs
        // parse to Inproc and serializing Inproc emits no field, so
        // existing config bytes are unchanged.
        let cfg = sample();
        assert_eq!(cfg.transport, TransportSpec::Inproc);
        assert!(!cfg.to_json_string().contains("transport"));
        for spec in [TransportSpec::Tcp, TransportSpec::Uds] {
            let mut wired = sample();
            wired.transport = spec;
            let text = wired.to_json_string();
            assert!(text.contains(&format!("\"transport\":\"{}\"", spec.as_str())));
            let back = ExperimentConfig::from_json(&Value::parse(&text).unwrap()).unwrap();
            assert_eq!(back, wired);
        }
        assert_eq!(TransportSpec::parse("tcp").unwrap(), TransportSpec::Tcp);
        assert!(TransportSpec::parse("carrier-pigeon").is_err());
        assert!(TransportSpec::Uds.is_wire() && !TransportSpec::Inproc.is_wire());
    }

    #[test]
    fn canonical_json_is_transport_free_key_sorted_and_site_independent() {
        // Strips the runtime-only transport field: a wired config and
        // its in-process twin canonicalize to the same bytes.
        let mut wired = sample();
        wired.transport = TransportSpec::Tcp;
        assert_eq!(wired.canonical_json(), sample().canonical_json());
        assert!(!wired.canonical_json().contains("transport"));
        // Construction-site independence: a from_json round trip (a
        // different construction order) emits identical bytes.
        let canon = sample().canonical_json();
        let back = ExperimentConfig::from_json(&Value::parse(&canon).unwrap()).unwrap();
        assert_eq!(back.canonical_json(), canon);
        // Keys emit sorted (BTreeMap object), so the first field is
        // alphabetically first, not declaration-first.
        assert!(canon.starts_with("{\"alpha\":"), "{canon}");
        // Any results-relevant field change moves the bytes.
        let mut changed = sample();
        changed.rounds += 1;
        assert_ne!(changed.canonical_json(), canon);
    }

    #[test]
    fn mode_spec_resolves_against_m() {
        assert_eq!(ExecModeSpec::Sync.resolve(4), ExecMode::Sync);
        assert_eq!(
            ExecModeSpec::SemiSync { participation: 0.5 }.resolve(4),
            ExecMode::SemiSync { quorum: 2 }
        );
        // ceil + clamp: participation never resolves below one arrival
        // or above M.
        assert_eq!(
            ExecModeSpec::SemiSync { participation: 0.1 }.resolve(4),
            ExecMode::SemiSync { quorum: 1 }
        );
        assert_eq!(
            ExecModeSpec::SemiSync { participation: 1.0 }.resolve(1),
            ExecMode::SemiSync { quorum: 1 }
        );
        assert_eq!(
            ExecModeSpec::Async { damping: 0.9 }.resolve(8),
            ExecMode::Async { damping: 0.9 }
        );
    }

    #[test]
    fn mode_spec_parses_cli_tokens() {
        assert_eq!(ExecModeSpec::parse("sync").unwrap(), ExecModeSpec::Sync);
        assert_eq!(
            ExecModeSpec::parse("semisync").unwrap(),
            ExecModeSpec::SemiSync { participation: 0.5 }
        );
        assert_eq!(
            ExecModeSpec::parse("semisync:0.75").unwrap(),
            ExecModeSpec::SemiSync { participation: 0.75 }
        );
        assert_eq!(
            ExecModeSpec::parse("async:0.9").unwrap(),
            ExecModeSpec::Async { damping: 0.9 }
        );
        assert!(ExecModeSpec::parse("sync:1").is_err());
        assert!(ExecModeSpec::parse("lockstep").is_err());
        assert!(ExecModeSpec::parse("async:zebra").is_err());
        // Out-of-range parameters fail at parse time, not mid-sweep.
        assert!(ExecModeSpec::parse("async:1.5").is_err());
        assert!(ExecModeSpec::parse("async:0").is_err());
        assert!(ExecModeSpec::parse("semisync:0").is_err());
        assert!(ExecModeSpec::parse("semisync:1.1").is_err());
        let bad = r#"{"kind": "async", "damping": 0.0}"#;
        assert!(ExecModeSpec::from_json(&Value::parse(bad).unwrap()).is_err());
        let bad = r#"{"kind": "semi_sync", "participation": 2.0}"#;
        assert!(ExecModeSpec::from_json(&Value::parse(bad).unwrap()).is_err());
    }

    #[test]
    fn defaults_fill_in() {
        let text = r#"{
            "name": "min", "m": 2, "rounds": 10,
            "workload": {"kind": "quadratic", "d": 30, "n_layers": 3, "t_comp": 0.0},
            "budget": {"mode": "per_direction", "t_comm": 1.0},
            "up_policy": {"kind": "kimad_uniform"},
            "down_policy": {"kind": "kimad_uniform"},
            "optimizer": {"gamma": 0.05},
            "uplink": {"kind": "constant", "bps": 1000.0},
            "downlink": {"kind": "constant", "bps": 1000.0}
        }"#;
        let cfg = ExperimentConfig::from_json(&Value::parse(text).unwrap()).unwrap();
        assert_eq!(cfg.alpha, 1.0);
        assert_eq!(cfg.participation, 1.0, "pre-population configs parse as dense");
        assert_eq!(cfg.cohorts, 0);
        assert!(!cfg.is_population());
        assert!(cfg.warm_start);
        assert!(!cfg.single_layer);
        assert_eq!(cfg.prior_bps, 0.0);
        assert_eq!(cfg.threads, 0);
        assert_eq!(cfg.shards, 0, "shards defaults to auto");
        assert_eq!(cfg.thread_cap, 0, "thread cap defaults to uncapped");
        assert_eq!(cfg.mode, ExecModeSpec::Sync);
        assert_eq!(cfg.compute, ComputeModel::Constant);
        assert_eq!(cfg.seed, 21);
    }

    #[test]
    fn clamp_parallelism_caps_explicit_and_auto_knobs() {
        // Explicit knobs clamp down to the budget, never up.
        let mut cfg = sample();
        cfg.threads = 8;
        cfg.shards = 8;
        cfg.clamp_parallelism(3);
        assert_eq!(cfg.threads, 3);
        assert_eq!(cfg.shards, 3);
        assert_eq!(cfg.thread_cap, 3);
        // Auto knobs stay auto (the small-work serial floor survives),
        // bounded by the cap the simulation resolves them against.
        let mut cfg = sample();
        cfg.threads = 0;
        cfg.shards = 0;
        cfg.clamp_parallelism(2);
        assert_eq!(cfg.threads, 0);
        assert_eq!(cfg.shards, 0);
        assert_eq!(cfg.thread_cap, 2);
        // A smaller pre-existing cap is never raised.
        cfg.clamp_parallelism(16);
        assert_eq!(cfg.thread_cap, 2);
        // Sub-budget explicit knobs are untouched; budget 0 means 1.
        let mut cfg = sample();
        cfg.threads = 1;
        cfg.shards = 2;
        cfg.clamp_parallelism(0);
        assert_eq!((cfg.threads, cfg.shards, cfg.thread_cap), (1, 1, 1));
    }

    #[test]
    fn workload_spec_parses_cli_tokens() {
        assert_eq!(
            WorkloadSpec::parse("quad").unwrap(),
            WorkloadSpec::Quadratic { d: 30, n_layers: 3, t_comp: 0.1 }
        );
        assert_eq!(
            WorkloadSpec::parse("quad:d=64,layers=6,tcomp=0.5").unwrap(),
            WorkloadSpec::Quadratic { d: 64, n_layers: 6, t_comp: 0.5 }
        );
        assert_eq!(
            WorkloadSpec::parse("deep:tiny").unwrap(),
            WorkloadSpec::DeepModel { preset: "tiny".into(), sigma: 0.3, t_comp: 0.0 }
        );
        assert_eq!(
            WorkloadSpec::parse("deep:e2e,sigma=0.5,tcomp=2").unwrap(),
            WorkloadSpec::DeepModel { preset: "e2e".into(), sigma: 0.5, t_comp: 2.0 }
        );
        // Bad tokens fail at parse time, not mid-grid.
        assert!(WorkloadSpec::parse("resnet").is_err());
        assert!(WorkloadSpec::parse("deep").is_err());
        assert!(WorkloadSpec::parse("quad:tiny").is_err());
        assert!(WorkloadSpec::parse("quad:d=0").is_err());
        assert!(WorkloadSpec::parse("quad:sigma=0.3").is_err());
        assert!(WorkloadSpec::parse("deep:tiny,d=30").is_err());
        assert!(WorkloadSpec::parse("deep:tiny,oops").is_err());
        assert!(WorkloadSpec::parse("quad:d=zebra").is_err());
        // Fractional dimensions are rejected, never silently truncated,
        // and non-finite/negative parameters fail at the CLI too.
        assert!(WorkloadSpec::parse("quad:d=2.7").is_err());
        assert!(WorkloadSpec::parse("quad:layers=1.9").is_err());
        assert!(WorkloadSpec::parse("quad:d=1e30").is_err());
        assert!(WorkloadSpec::parse("quad:tcomp=nan").is_err());
        assert!(WorkloadSpec::parse("quad:tcomp=-5").is_err());
        assert!(WorkloadSpec::parse("deep:tiny,sigma=inf").is_err());
    }

    #[test]
    fn workload_short_names() {
        assert_eq!(WorkloadSpec::parse("quad").unwrap().short_name(), "quad30l3");
        assert_eq!(WorkloadSpec::parse("deep:tiny").unwrap().short_name(), "deep-tiny");
        // Non-default parameters are embedded, so sweeping them in one
        // grid expands to distinct cell ids.
        assert_eq!(
            WorkloadSpec::parse("quad:tcomp=0.5").unwrap().short_name(),
            "quad30l3-tc0.5"
        );
        assert_eq!(
            WorkloadSpec::parse("deep:tiny,sigma=0.5,tcomp=2").unwrap().short_name(),
            "deep-tiny-sg0.5-tc2"
        );
        assert_ne!(
            WorkloadSpec::parse("deep:tiny,sigma=0.1").unwrap().short_name(),
            WorkloadSpec::parse("deep:tiny,sigma=0.5").unwrap().short_name()
        );
    }

    #[test]
    fn population_roundtrip_and_resolution() {
        let mut cfg = sample();
        cfg.m = 1_000_000;
        cfg.participation = 0.001;
        cfg.cohorts = 128;
        cfg.mode = ExecModeSpec::Sync;
        let back =
            ExperimentConfig::from_json(&Value::parse(&cfg.to_json_string()).unwrap()).unwrap();
        assert_eq!(back, cfg);
        assert!(cfg.is_population());
        assert_eq!(cfg.quorum(), 1000);
        assert_eq!(cfg.resolved_cohorts(), 128);
        assert_eq!(cfg.n_links(), 128);

        // Quorum ceils to >= 1 and clamps to M.
        cfg.m = 3;
        cfg.participation = 0.0001;
        assert_eq!(cfg.quorum(), 1);
        cfg.participation = 1.0;
        assert_eq!(cfg.quorum(), 3);

        // Auto cohorts: min(M, DEFAULT_COHORTS); explicit clamps to M.
        cfg.cohorts = 0;
        cfg.participation = 0.5;
        assert_eq!(cfg.resolved_cohorts(), 3);
        cfg.m = 1000;
        assert_eq!(cfg.resolved_cohorts(), DEFAULT_COHORTS);
        cfg.cohorts = 5000;
        assert_eq!(cfg.resolved_cohorts(), 1000);

        // Dense configs keep one link per worker.
        let dense = sample();
        assert!(!dense.is_population());
        assert_eq!(dense.n_links(), dense.m);
        // p = 1 with explicit cohorts routes through the population
        // engine (that is the bit-identity test's lever).
        let mut p1 = sample();
        p1.cohorts = p1.m;
        assert!(p1.is_population());
        assert_eq!(p1.n_links(), p1.m);

        // Out-of-range participation fails at parse time.
        let mut bad = sample();
        bad.participation = 0.0;
        assert!(ExperimentConfig::from_json(&Value::parse(&bad.to_json_string()).unwrap())
            .is_err());
        assert!(check_pop_participation(1.5).is_err());
        assert!(check_pop_participation(f64::NAN).is_err());
        assert_eq!(check_pop_participation(0.25).unwrap(), 0.25);
    }

    #[test]
    fn rejects_unknown_kinds() {
        let text = r#"{"kind": "nope"}"#;
        assert!(policy_from_json(&Value::parse(text).unwrap()).is_err());
        assert!(workload_from_json(&Value::parse(text).unwrap()).is_err());
        assert!(ExecModeSpec::from_json(&Value::parse(text).unwrap()).is_err());
        assert!(compute_from_json(&Value::parse(text).unwrap()).is_err());
    }
}
