//! Experiment configuration: JSON specs mirroring the paper's §4.2
//! setting, so every run is reproducible from a file under configs/.

use std::path::Path;

use crate::bandwidth::TraceSpec;
use crate::kimad::{BudgetParams, CompressPolicy};
use crate::util::json::Value;

/// Which workload drives gradients.
#[derive(Debug, Clone, PartialEq)]
pub enum WorkloadSpec {
    /// §4.1 quadratic: f(x) = ½ Σ a_i x_i², log-spaced a over [1,10].
    Quadratic { d: usize, n_layers: usize, t_comp: f64 },
    /// Deep model from artifacts/ (preset = tiny|small|e2e|big).
    DeepModel {
        preset: String,
        /// Dataset noise σ.
        sigma: f32,
        /// T_comp override; <= 0 means the §4.2 convention
        /// ModelSize / AverageBandwidth.
        t_comp: f64,
    },
}

#[derive(Debug, Clone, PartialEq)]
pub struct OptimizerSpec {
    pub gamma: f64,
    /// Per-layer weights w_i (empty = 1.0 everywhere).
    pub layer_weights: Vec<f64>,
}

/// A full experiment: the unit both the CLI and the benches consume.
#[derive(Debug, Clone, PartialEq)]
pub struct ExperimentConfig {
    pub name: String,
    /// Number of workers M.
    pub m: usize,
    pub workload: WorkloadSpec,
    pub budget: BudgetParams,
    pub up_policy: CompressPolicy,
    pub down_policy: CompressPolicy,
    pub optimizer: OptimizerSpec,
    /// Uplink bandwidth pattern (per-worker variants derived).
    pub uplink: TraceSpec,
    /// Downlink pattern.
    pub downlink: TraceSpec,
    /// Broadcast congestion coefficient α (§3.1); 1.0 = none.
    pub alpha: f64,
    /// Rounds to simulate.
    pub rounds: u64,
    /// Cold-start bandwidth prior (bits/s); <= 0 = mean of the pattern.
    pub prior_bps: f64,
    pub warm_start: bool,
    /// Use the whole model as ONE compression layer (plain Kimad);
    /// false = per-layer (Kimad+ granularity).
    pub single_layer: bool,
    /// Safety factor on the Eq. (2) budget (see SimConfig).
    pub budget_safety: f64,
    /// Worker-phase thread count (see `SimConfig::threads`): 0 = auto,
    /// 1 = serial. Results are bit-identical for every setting.
    pub threads: usize,
    pub seed: u64,
}

// ---------------------------------------------------------------------
// JSON codecs
// ---------------------------------------------------------------------

fn budget_to_json(b: &BudgetParams) -> Value {
    match b {
        BudgetParams::RoundBudget { t, t_comp } => Value::obj(vec![
            ("mode", Value::str("round_budget")),
            ("t", Value::num(*t)),
            ("t_comp", Value::num(*t_comp)),
        ]),
        BudgetParams::PerDirection { t_comm } => Value::obj(vec![
            ("mode", Value::str("per_direction")),
            ("t_comm", Value::num(*t_comm)),
        ]),
    }
}

fn budget_from_json(v: &Value) -> anyhow::Result<BudgetParams> {
    Ok(match v.get("mode")?.as_str()? {
        "round_budget" => BudgetParams::RoundBudget {
            t: v.get("t")?.as_f64()?,
            t_comp: v.get("t_comp")?.as_f64()?,
        },
        "per_direction" => BudgetParams::PerDirection { t_comm: v.get("t_comm")?.as_f64()? },
        other => anyhow::bail!("unknown budget mode '{other}'"),
    })
}

/// JSON codec for a [`CompressPolicy`] (shared with `scenarios`).
pub fn policy_to_json(p: &CompressPolicy) -> Value {
    match p {
        CompressPolicy::FixedRatio { ratio } => Value::obj(vec![
            ("kind", Value::str("fixed_ratio")),
            ("ratio", Value::num(*ratio)),
        ]),
        CompressPolicy::KimadUniform => {
            Value::obj(vec![("kind", Value::str("kimad_uniform"))])
        }
        CompressPolicy::KimadPlus { discretization, ratios } => Value::obj(vec![
            ("kind", Value::str("kimad_plus")),
            ("discretization", Value::num(*discretization as f64)),
            (
                "ratios",
                Value::Arr(ratios.iter().map(|&r| Value::num(r)).collect()),
            ),
        ]),
        CompressPolicy::WholeModelTopK => {
            Value::obj(vec![("kind", Value::str("whole_model_topk"))])
        }
    }
}

/// Inverse of [`policy_to_json`].
pub fn policy_from_json(v: &Value) -> anyhow::Result<CompressPolicy> {
    Ok(match v.get("kind")?.as_str()? {
        "fixed_ratio" => CompressPolicy::FixedRatio { ratio: v.get("ratio")?.as_f64()? },
        "kimad_uniform" => CompressPolicy::KimadUniform,
        "kimad_plus" => CompressPolicy::KimadPlus {
            discretization: v.get("discretization")?.as_usize()?,
            ratios: match v.opt("ratios") {
                None => vec![],
                Some(a) => a
                    .as_arr()?
                    .iter()
                    .map(|r| r.as_f64())
                    .collect::<anyhow::Result<Vec<_>>>()?,
            },
        },
        "whole_model_topk" => CompressPolicy::WholeModelTopK,
        other => anyhow::bail!("unknown policy kind '{other}'"),
    })
}

fn workload_to_json(w: &WorkloadSpec) -> Value {
    match w {
        WorkloadSpec::Quadratic { d, n_layers, t_comp } => Value::obj(vec![
            ("kind", Value::str("quadratic")),
            ("d", Value::num(*d as f64)),
            ("n_layers", Value::num(*n_layers as f64)),
            ("t_comp", Value::num(*t_comp)),
        ]),
        WorkloadSpec::DeepModel { preset, sigma, t_comp } => Value::obj(vec![
            ("kind", Value::str("deep_model")),
            ("preset", Value::str(preset.clone())),
            ("sigma", Value::num(*sigma as f64)),
            ("t_comp", Value::num(*t_comp)),
        ]),
    }
}

fn workload_from_json(v: &Value) -> anyhow::Result<WorkloadSpec> {
    Ok(match v.get("kind")?.as_str()? {
        "quadratic" => WorkloadSpec::Quadratic {
            d: v.get("d")?.as_usize()?,
            n_layers: v.get("n_layers")?.as_usize()?,
            t_comp: v.get("t_comp")?.as_f64()?,
        },
        "deep_model" => WorkloadSpec::DeepModel {
            preset: v.get("preset")?.as_str()?.to_string(),
            sigma: v.opt("sigma").and_then(|s| s.as_f64().ok()).unwrap_or(0.3) as f32,
            t_comp: v.opt("t_comp").and_then(|s| s.as_f64().ok()).unwrap_or(0.0),
        },
        other => anyhow::bail!("unknown workload kind '{other}'"),
    })
}

impl ExperimentConfig {
    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("name", Value::str(self.name.clone())),
            ("m", Value::num(self.m as f64)),
            ("workload", workload_to_json(&self.workload)),
            ("budget", budget_to_json(&self.budget)),
            ("up_policy", policy_to_json(&self.up_policy)),
            ("down_policy", policy_to_json(&self.down_policy)),
            (
                "optimizer",
                Value::obj(vec![
                    ("gamma", Value::num(self.optimizer.gamma)),
                    (
                        "layer_weights",
                        Value::Arr(
                            self.optimizer
                                .layer_weights
                                .iter()
                                .map(|&w| Value::num(w))
                                .collect(),
                        ),
                    ),
                ]),
            ),
            ("uplink", self.uplink.to_json()),
            ("downlink", self.downlink.to_json()),
            ("alpha", Value::num(self.alpha)),
            ("rounds", Value::num(self.rounds as f64)),
            ("prior_bps", Value::num(self.prior_bps)),
            ("warm_start", Value::Bool(self.warm_start)),
            ("single_layer", Value::Bool(self.single_layer)),
            ("budget_safety", Value::num(self.budget_safety)),
            ("threads", Value::num(self.threads as f64)),
            ("seed", Value::num(self.seed as f64)),
        ])
    }

    pub fn from_json(v: &Value) -> anyhow::Result<Self> {
        Ok(Self {
            name: v.get("name")?.as_str()?.to_string(),
            m: v.get("m")?.as_usize()?,
            workload: workload_from_json(v.get("workload")?)?,
            budget: budget_from_json(v.get("budget")?)?,
            up_policy: policy_from_json(v.get("up_policy")?)?,
            down_policy: policy_from_json(v.get("down_policy")?)?,
            optimizer: {
                let o = v.get("optimizer")?;
                OptimizerSpec {
                    gamma: o.get("gamma")?.as_f64()?,
                    layer_weights: match o.opt("layer_weights") {
                        None => vec![],
                        Some(a) => a
                            .as_arr()?
                            .iter()
                            .map(|w| w.as_f64())
                            .collect::<anyhow::Result<Vec<_>>>()?,
                    },
                }
            },
            uplink: TraceSpec::from_json(v.get("uplink")?)?,
            downlink: TraceSpec::from_json(v.get("downlink")?)?,
            alpha: v.opt("alpha").and_then(|a| a.as_f64().ok()).unwrap_or(1.0),
            rounds: v.get("rounds")?.as_u64()?,
            prior_bps: v.opt("prior_bps").and_then(|a| a.as_f64().ok()).unwrap_or(0.0),
            warm_start: v
                .opt("warm_start")
                .and_then(|a| a.as_bool().ok())
                .unwrap_or(true),
            single_layer: v
                .opt("single_layer")
                .and_then(|a| a.as_bool().ok())
                .unwrap_or(false),
            budget_safety: v
                .opt("budget_safety")
                .and_then(|a| a.as_f64().ok())
                .unwrap_or(1.0),
            threads: v
                .opt("threads")
                .and_then(|a| a.as_usize().ok())
                .unwrap_or(0),
            seed: v.opt("seed").and_then(|a| a.as_u64().ok()).unwrap_or(21),
        })
    }

    pub fn from_json_file(path: &Path) -> anyhow::Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        Self::from_json(&Value::parse(&text)?)
    }

    pub fn to_json_string(&self) -> String {
        self.to_json().to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> ExperimentConfig {
        ExperimentConfig {
            name: "fig8".into(),
            m: 4,
            workload: WorkloadSpec::DeepModel {
                preset: "e2e".into(),
                sigma: 0.3,
                t_comp: 0.0,
            },
            budget: BudgetParams::PerDirection { t_comm: 1.0 },
            up_policy: CompressPolicy::KimadPlus { discretization: 1000, ratios: vec![0.1, 0.5] },
            down_policy: CompressPolicy::KimadUniform,
            optimizer: OptimizerSpec { gamma: 0.01, layer_weights: vec![1.0, 0.5] },
            uplink: TraceSpec::SinSquared { eta: 300e6, theta: 0.7, delta: 30e6, phase: 0.0 },
            downlink: TraceSpec::Constant { bps: 1e9 },
            alpha: 1.0,
            rounds: 100,
            prior_bps: 0.0,
            warm_start: true,
            single_layer: false,
            budget_safety: 0.9,
            threads: 0,
            seed: 21,
        }
    }

    #[test]
    fn json_roundtrip() {
        let cfg = sample();
        let text = cfg.to_json_string();
        let back = ExperimentConfig::from_json(&Value::parse(&text).unwrap()).unwrap();
        assert_eq!(back, cfg);
    }

    #[test]
    fn roundtrip_all_variants() {
        let mut cfg = sample();
        cfg.workload = WorkloadSpec::Quadratic { d: 30, n_layers: 3, t_comp: 0.1 };
        cfg.budget = BudgetParams::RoundBudget { t: 1.0, t_comp: 0.2 };
        cfg.up_policy = CompressPolicy::FixedRatio { ratio: 0.2 };
        cfg.down_policy = CompressPolicy::WholeModelTopK;
        let back =
            ExperimentConfig::from_json(&Value::parse(&cfg.to_json_string()).unwrap()).unwrap();
        assert_eq!(back, cfg);
    }

    #[test]
    fn defaults_fill_in() {
        let text = r#"{
            "name": "min", "m": 2, "rounds": 10,
            "workload": {"kind": "quadratic", "d": 30, "n_layers": 3, "t_comp": 0.0},
            "budget": {"mode": "per_direction", "t_comm": 1.0},
            "up_policy": {"kind": "kimad_uniform"},
            "down_policy": {"kind": "kimad_uniform"},
            "optimizer": {"gamma": 0.05},
            "uplink": {"kind": "constant", "bps": 1000.0},
            "downlink": {"kind": "constant", "bps": 1000.0}
        }"#;
        let cfg = ExperimentConfig::from_json(&Value::parse(text).unwrap()).unwrap();
        assert_eq!(cfg.alpha, 1.0);
        assert!(cfg.warm_start);
        assert!(!cfg.single_layer);
        assert_eq!(cfg.prior_bps, 0.0);
        assert_eq!(cfg.threads, 0);
        assert_eq!(cfg.seed, 21);
    }

    #[test]
    fn rejects_unknown_kinds() {
        let text = r#"{"kind": "nope"}"#;
        assert!(policy_from_json(&Value::parse(text).unwrap()).is_err());
        assert!(workload_from_json(&Value::parse(text).unwrap()).is_err());
    }
}
