//! # Kimad: Adaptive Gradient Compression with Bandwidth Awareness
//!
//! A production-shaped reproduction of the paper (Xin, Ilin, Zhang,
//! Canini, Richtárik, 2023) as a three-layer rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the coordination contribution: a virtual-time
//!   Parameter-Server simulator ([`netsim`]), bandwidth monitoring
//!   ([`bandwidth`], §2.4/§3), the Eq. (2) compression budget,
//!   `A^compress` selection, the Kimad+ knapsack DP ([`kimad`],
//!   §3.1–§3.2), bidirectional EF21 ([`ef21`], §2.3/§3.3), the
//!   event-driven round engine with its layer-sharded server
//!   aggregation path ([`coordinator`], Algorithm 3) and the parallel
//!   scenario-matrix engine ([`scenarios`]). `docs/ARCHITECTURE.md`
//!   walks the whole engine end to end.
//! * **L2/L1 (build-time Python)** — the deep-model workload
//!   (transformer fwd/bwd in JAX, FFN/error-curve hot spots as Pallas
//!   kernels) AOT-lowered to HLO text and executed via [`runtime`]
//!   (PJRT). Python never runs on the request path.
//!
//! Quick start:
//!
//! ```no_run
//! use kimad::config::ExperimentConfig;
//! use kimad::driver::run_experiment;
//!
//! let cfg = ExperimentConfig::from_json_file("configs/fig8_kimad.json".as_ref()).unwrap();
//! let res = run_experiment(&cfg, Some("artifacts"), 4).unwrap();
//! println!("final loss = {}", res.records.last().unwrap().loss);
//! ```

// `unsafe` is banned crate-wide; the one exemption is the counting
// allocator (see bench/mod.rs), whose blocks carry SAFETY comments
// checked by `kimad tidy`.
#![deny(unsafe_code)]

pub mod analysis;
pub mod bandwidth;
pub mod bench;
pub mod compress;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod driver;
pub mod ef21;
pub mod kimad;
pub mod metrics;
pub mod model;
pub mod netsim;
pub mod optim;
pub mod quadratic;
pub mod reports;
pub mod runtime;
pub mod scenarios;
pub mod transport;
pub mod util;
