//! Metrics: time/loss/error series, CSV emission, run manifests.
//!
//! Every experiment (benches, examples, `kimad report ...`) writes its
//! series through this module so the paper's figures regenerate from
//! plain CSV with stable headers.

use std::io::Write;
use std::path::Path;

/// One named column-oriented series (e.g. a loss curve).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Series {
    pub name: String,
    pub points: Vec<(f64, f64)>,
}

impl Series {
    pub fn new(name: impl Into<String>) -> Self {
        Self { name: name.into(), points: Vec::new() }
    }

    pub fn push(&mut self, x: f64, y: f64) {
        self.points.push((x, y));
    }

    pub fn last_y(&self) -> Option<f64> {
        self.points.last().map(|p| p.1)
    }

    pub fn min_y(&self) -> Option<f64> {
        self.points
            .iter()
            .map(|p| p.1)
            .min_by(|a, b| a.total_cmp(b))
    }

    /// First x where y <= threshold (time-to-target metrics).
    pub fn first_x_below(&self, threshold: f64) -> Option<f64> {
        self.points.iter().find(|p| p.1 <= threshold).map(|p| p.0)
    }

    /// Mean of y values.
    pub fn mean_y(&self) -> Option<f64> {
        if self.points.is_empty() {
            None
        } else {
            Some(self.points.iter().map(|p| p.1).sum::<f64>() / self.points.len() as f64)
        }
    }
}

/// A bundle of series sharing an x-axis meaning, written as wide CSV
/// (x, series1, series2, ...) with x values merged by exact match or as
/// long CSV (series, x, y) when x axes differ.
#[derive(Debug, Default, Clone)]
pub struct SeriesSet {
    pub series: Vec<Series>,
}

impl SeriesSet {
    pub fn push(&mut self, s: Series) {
        self.series.push(s);
    }

    pub fn get(&self, name: &str) -> Option<&Series> {
        self.series.iter().find(|s| s.name == name)
    }

    /// Long-format CSV: `series,x,y` — robust to unaligned x axes.
    pub fn to_csv_long(&self, x_name: &str, y_name: &str) -> String {
        let mut out = format!("series,{x_name},{y_name}\n");
        for s in &self.series {
            for &(x, y) in &s.points {
                out.push_str(&format!("{},{x},{y}\n", s.name));
            }
        }
        out
    }

    pub fn write_csv(&self, path: &Path, x_name: &str, y_name: &str) -> anyhow::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::File::create(path)?;
        f.write_all(self.to_csv_long(x_name, y_name).as_bytes())?;
        Ok(())
    }
}

/// A paper table: rows x columns of f64 with labels, printed in the
/// same shape the paper reports.
#[derive(Debug, Clone)]
pub struct Table {
    pub title: String,
    pub col_labels: Vec<String>,
    pub row_labels: Vec<String>,
    pub cells: Vec<Vec<f64>>,
}

impl Table {
    pub fn new(title: impl Into<String>, cols: &[&str]) -> Self {
        Self {
            title: title.into(),
            col_labels: cols.iter().map(|s| s.to_string()).collect(),
            row_labels: Vec::new(),
            cells: Vec::new(),
        }
    }

    pub fn push_row(&mut self, label: impl Into<String>, cells: Vec<f64>) {
        assert_eq!(cells.len(), self.col_labels.len(), "row width mismatch");
        self.row_labels.push(label.into());
        self.cells.push(cells);
    }

    pub fn render(&self, unit: &str, decimals: usize) -> String {
        let mut out = format!("## {}\n\n|       |", self.title);
        for c in &self.col_labels {
            out.push_str(&format!(" {c} |"));
        }
        out.push_str("\n|-------|");
        out.push_str(&"--------|".repeat(self.col_labels.len()));
        out.push('\n');
        for (label, row) in self.row_labels.iter().zip(&self.cells) {
            out.push_str(&format!("| {label} |"));
            for v in row {
                out.push_str(&format!(" {v:.decimals$}{unit} |"));
            }
            out.push('\n');
        }
        out
    }

    pub fn to_csv(&self) -> String {
        let mut out = String::from("row");
        for c in &self.col_labels {
            out.push_str(&format!(",{c}"));
        }
        out.push('\n');
        for (label, row) in self.row_labels.iter().zip(&self.cells) {
            out.push_str(label);
            for v in row {
                out.push_str(&format!(",{v}"));
            }
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn series_stats() {
        let mut s = Series::new("loss");
        s.push(0.0, 3.0);
        s.push(1.0, 1.0);
        s.push(2.0, 2.0);
        assert_eq!(s.last_y(), Some(2.0));
        assert_eq!(s.min_y(), Some(1.0));
        assert_eq!(s.first_x_below(1.5), Some(1.0));
        assert_eq!(s.mean_y(), Some(2.0));
    }

    #[test]
    fn long_csv_format() {
        let mut set = SeriesSet::default();
        let mut s = Series::new("a");
        s.push(0.0, 1.0);
        set.push(s);
        let csv = set.to_csv_long("t", "v");
        assert_eq!(csv, "series,t,v\na,0,1\n");
    }

    #[test]
    fn table_render_and_csv() {
        let mut t = Table::new("Tab", &["1.0s", "0.5s"]);
        t.push_row("EF21", vec![486.1, 360.6]);
        t.push_row("Kimad", vec![385.2, 285.2]);
        let md = t.render("s", 1);
        assert!(md.contains("| EF21 | 486.1s | 360.6s |"));
        let csv = t.to_csv();
        assert!(csv.starts_with("row,1.0s,0.5s\n"));
        assert!(csv.contains("Kimad,385.2,285.2"));
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn table_width_checked() {
        let mut t = Table::new("T", &["a"]);
        t.push_row("r", vec![1.0, 2.0]);
    }
}
