//! `kimad` — the CLI launcher for the Kimad reproduction.
//!
//! Subcommands:
//!   train     run an experiment from a JSON config, write CSVs
//!   report    regenerate a paper figure/table (fig1, fig3..fig9,
//!             table1, table2, or `all`)
//!   scenarios run a scenario matrix (workloads × traces × policies ×
//!             modes × workers × safety × participation × shards) in
//!             parallel, one JSON summary per cell
//!   synthetic quick §4.1 quadratic comparison for one scenario
//!   trace     sample a bandwidth trace spec (JSON) to stdout
//!   bench     run the hot-path kernel suite + an end-to-end grid and
//!             emit a machine-readable BENCH_<host-tag>.json
//!   presets   list AOT model presets available in artifacts/
//!   gen-artifacts  write a native (JAX-free) artifact set — layout +
//!             seeded params + manifest — for deep-model presets
//!   worker    join a multi-process run: dial a coordinator and serve
//!             one worker id over the real wire (see rust/src/transport/)
//!   tidy      scan the crate's own sources against the invariant
//!             lints (see rust/src/analysis/); nonzero exit on findings
// Wall-clock allowlist file (ARCHITECTURE.md §6): this layer measures
// real time by design; clippy.toml bans the methods elsewhere.
#![allow(clippy::disallowed_methods)]

use std::path::{Path, PathBuf};

use kimad::config::ExperimentConfig;
use kimad::driver::run_experiment;
use kimad::metrics::{Series, SeriesSet};
use kimad::reports::{self, ReportCtx};
use kimad::util::atomicfile::write_atomic;
use kimad::util::cli::Args;
use kimad::util::json::Value;

const USAGE: &str = "\
kimad — adaptive gradient compression with bandwidth awareness (reproduction)

USAGE:
  kimad train --config <file.json> [--artifacts DIR] [--eval-batches N] [--csv OUT]
  kimad report <fig1|fig3..fig9|fig3to6|table1|table2|all> [--artifacts DIR] \\
               [--out-dir DIR] [--fast]
  kimad scenarios [--grid <grid.json>] [--out-dir DIR] [--threads N] \\
               [--cell-threads N] [--rounds N] [--modes sync,semisync,async] \\
               [--shards 1,2,4] [--workers 100,1000000] [--participation 1,0.001] \\
               [--workload 'quad:d=30,layers=3|deep:tiny'] \\
               [--transport inproc|tcp|uds] [--artifacts DIR] [--print-grid] \\
               [--resume | --fresh]
  kimad synthetic [--scenario xsmall|small|oscillation|high] [--fast] [--out-dir DIR]
  kimad bench [--quick] [--out FILE]
  kimad trace --spec '<json TraceSpec>' [--seconds S] [--step S]
  kimad presets [--artifacts DIR]
  kimad gen-artifacts [--presets tiny,small] [--out-dir DIR] [--seed N]
  kimad worker --connect <tcp:HOST:PORT|uds:PATH> --config <file.json> --id N \\
               [--artifacts DIR]
  kimad tidy [--json] [--fix-report] [--out FILE] [--root DIR]
";

/// Make the `kimad bench` allocation counts real: the library's
/// counting allocator only counts when a binary installs it.
#[global_allocator]
static GLOBAL: kimad::bench::CountingAlloc = kimad::bench::CountingAlloc;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(argv: &[String]) -> anyhow::Result<()> {
    let args = Args::parse(
        argv,
        &["fast", "fix-report", "fresh", "help", "json", "print-grid", "quick", "resume"],
    )?;
    if args.flag("help") || args.positional.is_empty() {
        println!("{USAGE}");
        return Ok(());
    }
    match args.positional[0].as_str() {
        "train" => train(&args),
        "report" => report(&args),
        "scenarios" => scenarios(&args),
        "synthetic" => synthetic(&args),
        "bench" => bench_cmd(&args),
        "trace" => trace(&args),
        "presets" => presets(&args),
        "gen-artifacts" => gen_artifacts(&args),
        "worker" => worker(&args),
        "tidy" => tidy(&args),
        other => anyhow::bail!("unknown subcommand '{other}'\n{USAGE}"),
    }
}

/// `kimad scenarios` — run a scenario matrix in parallel and write one
/// JSON summary per cell (plus index.json) under --out-dir.
fn scenarios(args: &Args) -> anyhow::Result<()> {
    let mut grid = match args.opt("grid") {
        Some(path) => kimad::scenarios::ScenarioGrid::from_json_file(path.as_ref())?,
        None => kimad::scenarios::ScenarioGrid::default_grid(),
    };
    if let Some(rounds) = args.opt("rounds") {
        grid.base.rounds = rounds
            .parse()
            .map_err(|e| anyhow::anyhow!("--rounds={rounds}: {e}"))?;
    }
    if let Some(modes) = args.opt("modes") {
        // Override the grid's execution-mode axis: comma-separated
        // sync|semisync[:participation]|async[:damping] tokens.
        grid.modes = modes
            .split(',')
            .map(|tok| {
                Ok(kimad::scenarios::NamedMode {
                    spec: kimad::config::ExecModeSpec::parse(tok.trim())?,
                })
            })
            .collect::<anyhow::Result<Vec<_>>>()?;
    }
    if let Some(shards) = args.opt("shards") {
        // Override the server-shard axis: comma-separated counts
        // (0 = auto). Sharding never changes results, so this axis
        // sweeps wall-clock scaling.
        grid.shard_counts = shards
            .split(',')
            .map(|tok| {
                tok.trim()
                    .parse::<usize>()
                    .map_err(|e| anyhow::anyhow!("--shards token '{tok}': {e}"))
            })
            .collect::<anyhow::Result<Vec<_>>>()?;
    }
    if let Some(workers) = args.opt("workers") {
        // Override the worker-count axis: comma-separated population
        // sizes. Combined with --participation < 1 these run as
        // sampled population cells, so million-client counts are fine.
        grid.worker_counts = workers
            .split(',')
            .map(|tok| {
                tok.trim()
                    .parse::<usize>()
                    .map_err(|e| anyhow::anyhow!("--workers token '{tok}': {e}"))
            })
            .collect::<anyhow::Result<Vec<_>>>()?;
    }
    if let Some(participation) = args.opt("participation") {
        // Override the participation axis: comma-separated fractions in
        // (0, 1]. 1 keeps the dense engine (and dense cell ids); p < 1
        // samples ceil(p*M) clients per round (Sync modes only).
        grid.participations = participation
            .split(',')
            .map(|tok| {
                let p: f64 = tok
                    .trim()
                    .parse()
                    .map_err(|e| anyhow::anyhow!("--participation token '{tok}': {e}"))?;
                kimad::config::check_pop_participation(p)?;
                Ok(p)
            })
            .collect::<anyhow::Result<Vec<_>>>()?;
    }
    if let Some(workloads) = args.opt("workload") {
        // Override the workload axis: |-separated tokens, each
        // quad[:d=..,layers=..,tcomp=..] or deep:<preset>[,sigma=..].
        // Cell ids use WorkloadSpec::short_name (quad30l3, deep-tiny).
        grid.workloads = workloads
            .split('|')
            .map(|tok| {
                Ok(kimad::scenarios::NamedWorkload::from_spec(
                    kimad::config::WorkloadSpec::parse(tok.trim())?,
                ))
            })
            .collect::<anyhow::Result<Vec<_>>>()?;
    }
    if let Some(dir) = args.opt("artifacts") {
        // Deep-model cells load from this artifact directory.
        grid.base.artifacts = Some(dir.to_string());
    }
    if let Some(t) = args.opt("transport") {
        // Run every cell over a real transport (coordinator + worker
        // processes exchanging frames) instead of in-process. Runtime
        // only: index.json stays byte-identical to an inproc run.
        grid.base.transport = kimad::config::TransportSpec::parse(t)?;
    }
    if args.flag("print-grid") {
        println!("{}", grid.to_json());
        return Ok(());
    }
    let threads = args.opt_usize("threads", 0)?;
    // Per-cell simulation-thread budget: 0 = the cooperative default
    // (available parallelism / matrix workers); an explicit value lets
    // a shard-axis sweep oversubscribe deliberately.
    let cell_threads = args.opt_usize("cell-threads", 0)?;
    let out_dir = PathBuf::from(args.opt_or("out-dir", "reports/scenarios"));
    // --resume reuses verified on-disk summaries (content-addressed
    // cell cache, docs/ARCHITECTURE.md §11); the default --fresh
    // re-executes and overwrites every cell.
    let mode = match (args.flag("resume"), args.flag("fresh")) {
        (true, true) => anyhow::bail!("--resume and --fresh are mutually exclusive"),
        (true, false) => kimad::scenarios::CacheMode::Resume,
        _ => kimad::scenarios::CacheMode::Fresh,
    };
    eprintln!(
        "running grid '{}': {} cells ({} workloads x {} traces x {} policies x {} modes \
         x {} worker counts x {} safety x {} participations x {} shard counts)...",
        grid.name,
        grid.n_cells(),
        grid.workloads.len(),
        grid.traces.len(),
        grid.policies.len(),
        grid.modes.len(),
        grid.worker_counts.len(),
        grid.safety_factors.len(),
        grid.participations.len(),
        grid.shard_counts.len()
    );
    // Surface silent neutering of a shard-axis sweep: under the
    // cooperative budget a requested shard count above the per-cell
    // thread budget runs clamped, so _sh2/_sh4 twins would compare
    // identical serialized runs without this note.
    let (_, budget) = kimad::scenarios::thread_budget(grid.n_cells(), threads);
    let per_cell = if cell_threads == 0 { budget } else { cell_threads };
    if let Some(&max_sh) = grid.shard_counts.iter().max() {
        if max_sh > per_cell {
            eprintln!(
                "note: shard counts up to {max_sh} will be clamped to the per-cell thread \
                 budget of {per_cell}; pass --cell-threads {max_sh} (or fewer --threads) to \
                 let the shard axis measure real parallelism"
            );
        }
    }
    let run = kimad::scenarios::run_matrix_cached(
        &grid,
        threads,
        cell_threads,
        Some(out_dir.as_path()),
        mode,
    )?;
    print!("{}", kimad::scenarios::render_table(&run.summaries, Some(&run.hits)));
    println!(
        "\ncache: {} hits, {} misses ({} stale re-ran; {} families built)",
        run.n_hits, run.n_executed, run.n_stale, run.n_families
    );
    println!(
        "{} cells in {:.2}s wall; summaries under {}",
        run.summaries.len(),
        run.elapsed_s,
        out_dir.display()
    );
    Ok(())
}

fn train(args: &Args) -> anyhow::Result<()> {
    let config = args
        .opt("config")
        .ok_or_else(|| anyhow::anyhow!("train requires --config <file.json>"))?;
    let artifacts = args.opt_or("artifacts", "artifacts");
    let eval_batches = args.opt_usize("eval-batches", 4)?;
    let cfg = ExperimentConfig::from_json_file(config.as_ref())?;
    eprintln!("running '{}' (M={}, {} rounds)...", cfg.name, cfg.m, cfg.rounds);
    let res = run_experiment(&cfg, Some(&artifacts), eval_batches)?;
    let last = res.records.last().expect("no rounds");
    println!(
        "rounds={} virtual_time={:.1}s mean_step={:.3}s final_loss={:.5} f_x={:.4e}",
        res.records.len(),
        res.total_time,
        res.mean_step_time(),
        last.loss,
        last.f_x
    );
    if let Some(e) = res.eval {
        println!(
            "eval: loss={:.4} top1={:.2}% top5={:.2}% (n={})",
            e.loss,
            e.top1 * 100.0,
            e.top5 * 100.0,
            e.n
        );
    }
    if let Some(path) = args.opt("csv") {
        let mut set = SeriesSet::default();
        let mut loss = Series::new("loss");
        let mut bits = Series::new("up_bits_w0");
        let mut fx = Series::new("f_x");
        for r in &res.records {
            loss.push(r.t_end(), r.loss);
            bits.push(r.t_start, r.workers[0].up_bits as f64);
            fx.push(r.t_end(), r.f_x);
        }
        set.push(loss);
        set.push(bits);
        set.push(fx);
        set.write_csv(path.as_ref(), "time_s", "value")?;
        eprintln!("wrote {path}");
    }
    Ok(())
}

fn report(args: &Args) -> anyhow::Result<()> {
    let id = args
        .positional
        .get(1)
        .ok_or_else(|| anyhow::anyhow!("report requires an id (e.g. fig8, table1, all)"))?;
    let ctx = ReportCtx {
        artifacts: args.opt_or("artifacts", "artifacts"),
        out_dir: PathBuf::from(args.opt_or("out-dir", "reports")),
        fast: args.flag("fast"),
    };
    std::fs::create_dir_all(&ctx.out_dir)?;
    if id == "all" {
        for id in reports::ALL_REPORTS {
            println!("{}", reports::generate(id, &ctx)?);
        }
    } else {
        println!("{}", reports::generate(id, &ctx)?);
    }
    Ok(())
}

fn synthetic(args: &Args) -> anyhow::Result<()> {
    use kimad::reports::synthetic::Scenario;
    let scn = match args.opt_or("scenario", "xsmall").as_str() {
        "xsmall" => Scenario::XSmall,
        "small" => Scenario::Small,
        "oscillation" => Scenario::Oscillation,
        "high" => Scenario::High,
        other => anyhow::bail!("unknown scenario '{other}'"),
    };
    let ctx = ReportCtx {
        artifacts: "artifacts".into(),
        out_dir: PathBuf::from(args.opt_or("out-dir", "reports")),
        fast: args.flag("fast"),
    };
    std::fs::create_dir_all(&ctx.out_dir)?;
    println!("{}", kimad::reports::synthetic::generate_one(&ctx, scn)?);
    Ok(())
}

/// `kimad bench` — run the hot-path kernel suite plus the end-to-end
/// reference grid(s) and write one BENCH_<host-tag>.json (schema:
/// rust/src/bench/report.rs; gated in CI by scripts/bench_check).
fn bench_cmd(args: &Args) -> anyhow::Result<()> {
    let quick = args.flag("quick");
    let report = kimad::bench::run(quick)?;
    let out = match args.opt("out") {
        Some(p) => PathBuf::from(p),
        None => PathBuf::from(format!("BENCH_{}.json", report.config.host)),
    };
    write_atomic(&out, report.to_json().to_string().as_bytes())?;
    for e in &report.e2e {
        println!(
            "e2e {}: {} cells in {:.0} ms ({:.2} cells/s, build {:.0} ms)",
            e.grid, e.cells, e.wall_ms, e.cells_per_sec, e.build_ms
        );
    }
    println!("wrote {}", out.display());
    Ok(())
}

/// `kimad worker` — the worker half of a multi-process run. Normally
/// spawned by the coordinating `kimad scenarios --transport ...`
/// process, but speaks a stable enough protocol to launch by hand.
fn worker(args: &Args) -> anyhow::Result<()> {
    let addr = args
        .opt("connect")
        .ok_or_else(|| anyhow::anyhow!("worker requires --connect <tcp:HOST:PORT|uds:PATH>"))?;
    let config = args
        .opt("config")
        .ok_or_else(|| anyhow::anyhow!("worker requires --config <file.json>"))?;
    let id_text = args
        .opt("id")
        .ok_or_else(|| anyhow::anyhow!("worker requires --id <N>"))?;
    let id: usize = id_text.parse().map_err(|e| anyhow::anyhow!("--id={id_text}: {e}"))?;
    let cfg = ExperimentConfig::from_json_file(config.as_ref())?;
    kimad::transport::worker::run_worker(&cfg, args.opt("artifacts"), addr, id)
}

fn trace(args: &Args) -> anyhow::Result<()> {
    let spec_text = args
        .opt("spec")
        .ok_or_else(|| anyhow::anyhow!("trace requires --spec '<json>'"))?;
    let spec = kimad::bandwidth::TraceSpec::from_json(&Value::parse(spec_text)?)?;
    let seconds = args.opt_f64("seconds", 60.0)?;
    let step = args.opt_f64("step", 0.5)?;
    let tr = spec.build();
    println!("time_s,bps");
    let mut t = 0.0;
    while t <= seconds {
        println!("{t},{}", tr.at(t));
        t += step;
    }
    Ok(())
}

fn presets(args: &Args) -> anyhow::Result<()> {
    let store = kimad::runtime::ArtifactStore::open(args.opt_or("artifacts", "artifacts"))?;
    for p in store.model_presets() {
        let m = store.model(p)?;
        println!("{p}: {} params ({})", m.n_params, m.train_hlo);
    }
    Ok(())
}

/// `kimad gen-artifacts` — write a native (JAX-free) artifact set:
/// layout + seeded initial params + manifest per preset. Enough for
/// the native deep-model backend (and CI); `make artifacts` still
/// produces the full HLO set for PJRT builds.
fn gen_artifacts(args: &Args) -> anyhow::Result<()> {
    let presets: Vec<String> = args
        .opt_or("presets", "tiny")
        .split(',')
        .map(|p| p.trim().to_string())
        .filter(|p| !p.is_empty())
        .collect();
    let out_dir = PathBuf::from(args.opt_or("out-dir", "artifacts"));
    let seed = args.opt_usize("seed", 21)? as u64;
    let store = kimad::runtime::write_native_artifacts(&out_dir, &presets, seed)?;
    for p in store.model_presets() {
        let m = store.model(p)?;
        println!("{p}: {} params -> {}", m.n_params, out_dir.display());
    }
    Ok(())
}

/// `kimad tidy` — run the static-analysis pass over the crate's own
/// sources (see rust/src/analysis/). Exits nonzero on any diagnostic,
/// including unused allows, so CI and the tier-1 test agree exactly.
fn tidy(args: &Args) -> anyhow::Result<()> {
    let root = match args.opt("root") {
        Some(r) => PathBuf::from(r),
        None => kimad::analysis::default_root(),
    };
    if !root.join("src").is_dir() {
        anyhow::bail!("tidy: no src/ under {} (use --root DIR)", root.display());
    }
    let report = kimad::analysis::scan_root(&root)?;
    let rendered = if args.flag("json") {
        report.to_json().to_string()
    } else {
        report.render_human(args.flag("fix-report"))
    };
    match args.opt("out") {
        Some(p) => {
            write_atomic(Path::new(p), rendered.as_bytes())?;
            println!("wrote {p}");
        }
        None => print!("{rendered}"),
    }
    if !report.clean() {
        anyhow::bail!("tidy: {} diagnostic(s)", report.diagnostics.len());
    }
    Ok(())
}
