//! Offline stand-in for the `anyhow` crate.
//!
//! The build runs with no network access, so instead of the registry
//! crate this vendored shim provides the exact API surface the `kimad`
//! workspace uses: [`Error`], [`Result`], and the [`anyhow!`],
//! [`bail!`] and [`ensure!`] macros. Errors carry a formatted message
//! plus the display of any wrapped source error; `{:#}` renders the
//! same as `{}` (there is no context chain to expand).

use std::fmt;

/// A message-carrying error type, convertible from any std error.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from a preformatted message.
    pub fn msg(msg: impl Into<String>) -> Self {
        Self { msg: msg.into() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Self {
        let mut msg = e.to_string();
        let mut source = e.source();
        while let Some(s) = source {
            msg.push_str(": ");
            msg.push_str(&s.to_string());
            source = s.source();
        }
        Self { msg }
    }
}

/// `std::result::Result` defaulted to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string (or a displayable value).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(::std::format!("{}", $err))
    };
}

/// Return early with an [`Error`] built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)+) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)+))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::Error::msg(::std::concat!(
                "condition failed: ",
                ::std::stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($arg)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails(flag: bool) -> Result<u32> {
        ensure!(flag, "flag was {flag}");
        Ok(7)
    }

    #[test]
    fn macros_and_conversions() {
        let e = anyhow!("x = {}", 3);
        assert_eq!(e.to_string(), "x = 3");
        assert_eq!(format!("{e:#}"), "x = 3");
        assert_eq!(fails(true).unwrap(), 7);
        assert_eq!(fails(false).unwrap_err().to_string(), "flag was false");

        fn bails() -> Result<()> {
            bail!("boom {}", 1)
        }
        assert_eq!(bails().unwrap_err().to_string(), "boom 1");

        fn io() -> Result<String> {
            Ok(std::fs::read_to_string("/definitely/not/a/file")?)
        }
        assert!(io().unwrap_err().to_string().contains("No such file"));

        let parsed: std::result::Result<f64, _> = "abc".parse();
        let e: Error = parsed.unwrap_err().into();
        assert!(!e.to_string().is_empty());
    }
}
